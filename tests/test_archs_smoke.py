"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU with correct shapes and
no NaNs.  Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config, smoke
from repro.models import model_zoo


def _inputs(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(key, 1), (B, S), 0,
                                cfg.vocab_size)
    kwargs = {}
    if cfg.frontend == "vision_stub":
        kwargs["frontend_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.enc_dec:
        kwargs["enc_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model)).astype(jnp.bfloat16)
    return tokens, labels, kwargs


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_forward_and_train_step(name, rng_key):
    cfg = smoke(get_config(name))
    bundle = model_zoo.build(cfg, remat=False)
    params = bundle.init(rng_key)
    tokens, labels, kwargs = _inputs(cfg, rng_key)

    logits, aux = bundle.apply_fn(params, tokens, **kwargs)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(bundle.loss_fn)(params, tokens, labels,
                                                     **kwargs)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert gnorm > 0, "gradients must flow"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_prefill_decode(name, rng_key):
    cfg = smoke(get_config(name))
    bundle = model_zoo.build(cfg, remat=False)
    params = bundle.init(rng_key)
    tokens, _, kwargs = _inputs(cfg, rng_key)
    logits, cache = bundle.prefill_fn(params, tokens, max_len=36, **kwargs)
    assert logits.shape == (2, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = bundle.decode_fn(params, tok, cache, jnp.int32(32))
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_exact_published_config(name):
    """The full config matches the assignment spec (no allocation)."""
    cfg = get_config(name)
    spec = {
        "internvl2_76b": (80, 8192, 64, 8, 28672, 128256),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen3_moe_235b_a22b": (94, 4096, 64, 4, 1536, 151936),
        "phi35_moe_42b_a66b": (32, 4096, 32, 8, 6400, 32064),
        "falcon_mamba_7b": (64, 4096, 0, 0, 0, 65024),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "jamba_v01_52b": (32, 4096, 32, 8, 14336, 65536),
    }[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec


def test_moe_configs():
    q = get_config("qwen3_moe_235b_a22b")
    assert (q.n_experts, q.experts_per_token) == (128, 8)
    p = get_config("phi35_moe_42b_a66b")
    assert (p.n_experts, p.experts_per_token) == (16, 2)
    j = get_config("jamba_v01_52b")
    assert (j.n_experts, j.experts_per_token) == (16, 2)
    kinds = j.layer_kinds()
    assert sum(k.mixer == "attn" for k in kinds) == 4  # 1:7 over 32 layers
    assert sum(k.mlp == "moe" for k in kinds) == 16    # alternate layers


def test_gemma3_pattern():
    g = get_config("gemma3_4b")
    kinds = g.layer_kinds()
    assert sum(k.mixer == "attn" for k in kinds) == 5      # global every 6th
    assert sum(k.mixer == "attn_local" for k in kinds) == 29
    assert all(k.window == 1024 for k in kinds if k.mixer == "attn_local")


def test_long_context_eligibility():
    from repro.configs import cell_is_runnable
    for name in ARCH_NAMES:
        cfg = get_config(name)
        ok, why = cell_is_runnable(cfg, SHAPES["long_500k"])
        if name in ("gemma3_4b", "falcon_mamba_7b", "jamba_v01_52b"):
            assert ok, name
        else:
            assert not ok and why, name


def test_param_counts_close_to_published():
    """Total parameter counts should be in the right ballpark (the names
    encode the sizes)."""
    expected = {
        "llama3_8b": (8.0e9, 0.25), "deepseek_67b": (67e9, 0.25),
        "qwen3_moe_235b_a22b": (235e9, 0.3), "falcon_mamba_7b": (7e9, 0.35),
        "jamba_v01_52b": (52e9, 0.3), "phi35_moe_42b_a66b": (42e9, 0.3),
        "minitron_4b": (4e9, 0.4), "gemma3_4b": (4e9, 0.45),
        "internvl2_76b": (76e9, 0.25), "whisper_small": (0.24e9, 0.6),
    }
    for name, (target, tol) in expected.items():
        cfg = get_config(name)
        n = model_zoo.build(cfg).n_params()
        assert abs(n - target) / target < tol, (name, n, target)
