"""`hypothesis` import-or-fallback shim for the property-based test modules.

When `hypothesis` is installed (see requirements-dev.txt) the real
`given` / `settings` / `strategies` are re-exported unchanged and the
property tests get full shrinking + example databases.  When it is absent
(the minimal tier-1 container) a deterministic mini-implementation takes
over: each strategy is a seeded sampler and `@given` replays
`max_examples` pseudo-random draws through the test body.  Either way all
test modules *collect* — the suite never ERRORs on a missing dev
dependency (ISSUE 1 satellite).

Only the strategy surface the suite actually uses is implemented:
`st.integers(lo, hi)`, `st.floats(lo, hi)`, `st.sampled_from(seq)`,
positional `@given`, and `@settings(max_examples=..., deadline=...)`.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        """Drawn values fill the trailing positional parameters of the test
        (matching how this suite calls hypothesis); any leading parameters
        stay visible to pytest as fixtures."""
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            fixture_names = names[:len(names) - len(strategies)]

            def runner(**fixture_kwargs):
                # @settings may sit outside @given (attribute lands on
                # runner) or inside (lands on fn) — honor both orders
                n = getattr(runner, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = [s.example_from(rng) for s in strategies]
                    fn(*[fixture_kwargs[p] for p in fixture_names], *drawn)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__signature__ = inspect.Signature(
                [sig.parameters[p] for p in fixture_names])
            return runner
        return deco
