"""The kernel-dispatch seam (`kernels/dispatch.py`): per-process mode
resolution, the env-var override, `KernelConfig` validation, and the
three kernel ops routing through one seam — plus the env hot path
(`alex`/`carmi` `run_reads`) staying numerically equal under Pallas
probe modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.index import alex, carmi
from repro.kernels import dispatch
from repro.kernels.dispatch import KernelConfig

on_cpu = jax.default_backend() not in ("gpu", "tpu")


# ---------------------------------------------------------- resolution
def test_resolve_concrete_modes_pass_through():
    for m in ("compiled", "interpret", "ref"):
        assert dispatch.resolve(m) == m


def test_resolve_rejects_unknown_mode():
    with pytest.raises(ValueError, match="kernel mode"):
        dispatch.resolve("fast")


def test_auto_mode_backend_rule():
    """auto/None resolve to ref on CPU, compiled on accelerators —
    and the answer is cached (one posture per process)."""
    got = dispatch.resolve(None)
    assert got == ("ref" if on_cpu else "compiled")
    assert dispatch.resolve("auto") == got
    assert dispatch._auto_mode() is dispatch._auto_mode()


def test_env_var_override(monkeypatch):
    monkeypatch.setenv(dispatch._ENV_VAR, "interpret")
    dispatch._auto_mode.cache_clear()
    try:
        assert dispatch.resolve(None) == "interpret"
        monkeypatch.setenv(dispatch._ENV_VAR, "bogus")
        dispatch._auto_mode.cache_clear()
        with pytest.raises(ValueError):
            dispatch.resolve(None)
    finally:
        monkeypatch.delenv(dispatch._ENV_VAR)
        dispatch._auto_mode.cache_clear()


def test_interpret_flag():
    assert dispatch.interpret_flag("interpret") is True
    assert dispatch.interpret_flag("compiled") is False


# --------------------------------------------------------- KernelConfig
def test_kernel_config_validation():
    with pytest.raises(ValueError):
        KernelConfig(mode="pallas")
    with pytest.raises(ValueError):
        KernelConfig(probe_tile=100)        # not a pow2
    with pytest.raises(ValueError):
        KernelConfig(probe_tile=-8)
    assert KernelConfig(probe_tile=256).probe_tile == 256
    assert KernelConfig().resolved() == dispatch.resolve(None)


def test_kernel_config_hashes_by_value():
    """Two equal configs are one program-cache key (frozen dataclass)."""
    assert KernelConfig() == KernelConfig()
    assert hash(KernelConfig()) == hash(KernelConfig())
    assert KernelConfig(mode="interpret") != KernelConfig()


# ----------------------------------------------- ops route through modes
def test_mha_mode_routing(rng_key):
    """flash_attention's op takes the one `mode` arg: interpret runs the
    kernel body, ref the oracle — same numbers either way."""
    from repro.kernels.flash_attention.ops import mha
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 16), jnp.float32)
    got = mha(q, k, v, mode="interpret")
    want = mha(q, k, v, mode="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)
    with pytest.raises(ValueError):
        mha(q, k, v, mode="bogus")


def test_mamba_scan_mode_routing(rng_key):
    from repro.kernels.mamba_scan.ops import scan
    ks = jax.random.split(rng_key, 4)
    u = jax.random.normal(ks[0], (1, 64, 16), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 64, 16)))
    b_mat = jax.random.normal(ks[2], (1, 64, 4), jnp.float32)
    c_mat = jax.random.normal(ks[3], (1, 64, 4), jnp.float32)
    a = -jnp.exp(jax.random.normal(rng_key, (16, 4)) * 0.3)
    got = scan(u, dt, b_mat, c_mat, a, mode="interpret", chunk=64)
    want = scan(u, dt, b_mat, c_mat, a, mode="ref", chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- env hot path
def _alex_params():
    return {k: jnp.float32(v) for k, v in alex.DEFAULTS.items()}


def _carmi_params():
    return {k: jnp.float32(v) for k, v in carmi.DEFAULTS.items()}


def test_alex_run_reads_kernel_mode_parity(rng_key):
    """run_reads under the Pallas probe gate returns numbers equal to
    the default searchsorted reference path (the probe is exact)."""
    keys = jnp.sort(jax.random.uniform(rng_key, (2048,)))
    reads = jax.random.uniform(jax.random.fold_in(rng_key, 1), (256,)) \
        * 1.4 - 0.2                          # includes out-of-range
    idx = alex.build(keys, _alex_params())
    ns_ref, m_ref = alex.run_reads(idx, reads)
    ns_k, m_k = alex.run_reads(idx, reads,
                               kernel=KernelConfig(mode="interpret"))
    np.testing.assert_array_equal(np.asarray(ns_ref), np.asarray(ns_k))
    for f in m_ref:
        np.testing.assert_array_equal(np.asarray(m_ref[f]),
                                      np.asarray(m_k[f]), err_msg=f)


def test_carmi_run_reads_kernel_mode_parity(rng_key):
    p = _carmi_params()
    keys = jnp.sort(jax.random.uniform(rng_key, (2048,)))
    reads = jax.random.uniform(jax.random.fold_in(rng_key, 1), (256,)) \
        * 1.4 - 0.2
    idx = carmi.build(keys, p)
    ns_ref, m_ref = carmi.run_reads(idx, reads, p)
    ns_k, m_k = carmi.run_reads(idx, reads, p,
                                kernel=KernelConfig(mode="interpret"))
    np.testing.assert_array_equal(np.asarray(ns_ref), np.asarray(ns_k))
    for f in m_ref:
        np.testing.assert_array_equal(np.asarray(m_ref[f]),
                                      np.asarray(m_k[f]), err_msg=f)


def test_env_config_threads_kernel(rng_key):
    """evaluate_params carries EnvConfig.kernel into run_reads: the
    probe-gated env step equals the default bitwise."""
    import dataclasses

    from repro.index.env import EnvConfig, evaluate_params
    from repro.index.workloads import wr_workload
    cfg = EnvConfig(index_type="alex")
    assert cfg.kernel == KernelConfig()
    keys = jnp.sort(jax.random.uniform(rng_key, (2048,)))
    wl, _ = wr_workload(jax.random.fold_in(rng_key, 7), keys, 0.7,
                        total=512)
    p = _alex_params()
    r0, _, _ = evaluate_params(cfg, p, keys, wl, 0.7)
    cfg_k = dataclasses.replace(cfg, kernel=KernelConfig(mode="interpret"))
    r1, _, _ = evaluate_params(cfg_k, p, keys, wl, 0.7)
    np.testing.assert_array_equal(np.asarray(r0), np.asarray(r1))
