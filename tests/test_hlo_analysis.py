"""HLO parser validation: scan-based totals must match XLA's own
cost_analysis on an unrolled twin, and trip counts must come from the
trip_scope markers."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.module import trip_scope
from repro.runtime import hlo_analysis as ha


L, D, F, B = 6, 128, 256, 16


def _body(x, ws):
    a, b = ws
    h = jax.nn.relu(jnp.einsum("bd,df->bf", x, a))
    return jnp.einsum("bf,fd->bd", h, b), None


def _scan_fn(w1, w2, x):
    with trip_scope(L, "layers"):
        out, _ = jax.lax.scan(_body, x, (w1, w2))
    return out.sum()


def _unroll_fn(w1, w2, x):
    for i in range(L):
        x, _ = _body(x, (w1[i], w2[i]))
    return x.sum()


@pytest.fixture(scope="module")
def compiled_pair():
    w1 = jax.ShapeDtypeStruct((L, D, F), jnp.float32)
    w2 = jax.ShapeDtypeStruct((L, F, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    scan = jax.jit(_scan_fn).lower(w1, w2, x).compile()
    unroll = jax.jit(_unroll_fn).lower(w1, w2, x).compile()
    return scan, unroll


def test_trip_count_from_scope(compiled_pair):
    scan, _ = compiled_pair
    res = ha.analyze(scan.as_text())
    assert list(res.while_trips.values()) == [L]
    assert not res.warnings


def test_scan_flops_match_unrolled_cost_analysis(compiled_pair):
    scan, unroll = compiled_pair
    res_scan = ha.analyze(scan.as_text())
    res_unroll = ha.analyze(unroll.as_text())
    xla_unroll = float(ha.xla_cost_analysis(unroll)["flops"])
    analytic = L * 2 * (2 * B * D * F)
    # parser on scan == parser on unroll == XLA on unroll == analytic (±5%)
    for val in (res_scan.flops, res_unroll.flops, xla_unroll):
        assert abs(val - analytic) / analytic < 0.05, val


def test_xla_cost_analysis_undercounts_scan(compiled_pair):
    """The reason this module exists: XLA counts while bodies once."""
    scan, _ = compiled_pair
    xla_scan = float(ha.xla_cost_analysis(scan)["flops"])
    res_scan = ha.analyze(scan.as_text())
    assert xla_scan < res_scan.flops / 2


def test_bytes_sane(compiled_pair):
    scan, _ = compiled_pair
    res = ha.analyze(scan.as_text())
    weight_bytes = L * 2 * D * F * 4
    io_bytes = B * D * 4
    # at least one read of all weights + activations; at most ~10x slack
    assert res.bytes_accessed > weight_bytes + io_bytes
    assert res.bytes_accessed < 10 * (weight_bytes + 4 * L * B * F * 4)


def test_roofline_terms():
    a = ha.HLOAnalysis(flops=197e12, bytes_accessed=819e9,
                       collective_bytes=50e9)
    t = ha.roofline(a, model_flops_per_device=98.5e12)
    assert abs(t.compute_s - 1.0) < 1e-6
    assert abs(t.memory_s - 1.0) < 1e-6
    assert abs(t.collective_s - 1.0) < 1e-6
    assert t.useful_ratio == pytest.approx(0.5)
    assert t.dominant in ("compute", "memory", "collective")


def test_collective_parsing_small_mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (covered by dry-run)")
