"""Multi-device integration tests, run in subprocesses so the host-platform
device count doesn't leak into the rest of the suite."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import SHAPES, get_config
from repro.launch.steps import analytic_memory, lower_cell, plan_cell
from repro.launch.train import scale_config
from repro.runtime import hlo_analysis as ha

mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}

# one cell per family, reduced configs, on the small mesh
for arch, shape_name in (("llama3_8b", "train_4k"),
                         ("qwen3_moe_235b_a22b", "decode_32k"),
                         ("falcon_mamba_7b", "train_4k"),
                         ("whisper_small", "prefill_32k")):
    cfg = scale_config(get_config(arch), "tiny")
    shape = dataclasses.replace(SHAPES[shape_name], global_batch=8,
                                seq_len=256)
    plan = plan_cell(cfg, shape, mesh)
    compiled = lower_cell(plan).compile()
    analysis = ha.analyze(compiled.as_text(), n_devices=8)
    mem = analytic_memory(plan)
    out[f"{arch}:{shape_name}"] = {
        "flops": analysis.flops,
        "collective_bytes": analysis.collective_bytes,
        "mem_total": mem["total"],
        "trip_warnings": len([w for w in analysis.warnings
                              if "trip" in w]),
    }

# elastic: save on 2x4 mesh, restore on 4x2
from repro.checkpoint import ckpt
from repro.runtime.elastic import restore_on_mesh
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
axes = {"w": ("mlp", "embed")}
ckpt.save("/tmp/elastic_test_ckpt", 0, tree)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
restored, _ = restore_on_mesh("/tmp/elastic_test_ckpt", 0, tree, axes, mesh2)
assert np.array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
out["elastic"] = {"ok": True,
                  "sharded": str(restored["w"].sharding.spec)}

# compressed cross-pod grads on a (2,2,2) pod mesh
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.optim.grad_compress import init_error_state, make_pod_grad_fn
mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
W = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
def loss_fn(params, x, y):
    return jnp.mean((x @ params["w"] - y) ** 2)
params = {"w": jax.device_put(W, NamedSharding(mesh3, P(None, "model")))}
bsh = NamedSharding(mesh3, P(("pod", "data"), None))
batch = {"x": jax.device_put(jnp.ones((32, 16)), bsh),
         "y": jax.device_put(jnp.zeros((32, 16)), bsh)}
err = init_error_state(params)
fn = make_pod_grad_fn(loss_fn, mesh3, params, batch)
with mesh3:
    loss, grads, err2 = jax.jit(fn)(params, err, batch)
    txt = jax.jit(fn).lower(params, err, batch).compile().as_text()
_, g_ref = jax.value_and_grad(loss_fn)(
    {"w": W}, x=jnp.ones((32, 16)), y=jnp.zeros((32, 16)))
rel = float(jnp.max(jnp.abs(grads["w"] - g_ref["w"]))
            / jnp.maximum(jnp.max(jnp.abs(g_ref["w"])), 1e-9))
out["grad_compress"] = {
    "rel_err": rel,
    "int16_allreduce": "s16" in txt and "all-reduce" in txt,
}
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subproc_results():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        timeout=540, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                          "HOME": "/root",
                          # force CPU: a stray libtpu otherwise burns
                          # minutes probing cloud TPU metadata
                          "JAX_PLATFORMS": "cpu"}, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[0][len("RESULT "):])


def test_cells_lower_on_small_mesh(subproc_results):
    for key in ("llama3_8b:train_4k", "qwen3_moe_235b_a22b:decode_32k",
                "falcon_mamba_7b:train_4k", "whisper_small:prefill_32k"):
        rec = subproc_results[key]
        assert rec["flops"] > 0
        assert rec["mem_total"] > 0
        assert rec["trip_warnings"] == 0


def test_train_cells_have_collectives(subproc_results):
    assert subproc_results["llama3_8b:train_4k"]["collective_bytes"] > 0


def test_elastic_restore_other_mesh(subproc_results):
    assert subproc_results["elastic"]["ok"]


def test_compressed_grads_on_pod_mesh(subproc_results):
    rec = subproc_results["grad_compress"]
    assert rec["rel_err"] < 0.05
    assert rec["int16_allreduce"]
