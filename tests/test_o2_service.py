"""O2 continuous tuning inside the TuningService (launch/serving/).

* single-tenant parity — a slots=1 O2-enabled service stream makes the
  same per-window divergence/swap decisions as `O2System.tune_window` on
  identical windows, fine-tunes to bitwise-identical offline params, and
  fills a bitwise-identical replay;
* swap plumbing — a forced offline win hot-swaps pool params with zero
  re-traces of the K-ladder compiled-program cache; a forced loss leaves
  the pools untouched;
* divergence-monitor bookkeeping — every window (including the reference
  window) records a divergence entry and re-anchors are tracked;
* replay ingestion — `SequenceReplay.add_episode` is bitwise-equivalent
  to sequential `add` calls, including `step_left` back-fill and ring
  wraparound.
"""
import jax
import numpy as np
import pytest

import repro.launch.serving.o2_runtime as o2_runtime
import repro.launch.serving.programs as programs
from repro.core.ddpg import DDPGConfig
from repro.core.litune import LITune, LITuneConfig
from repro.core.o2 import DivergenceMonitor, O2Config, O2System
from repro.core.replay import SequenceReplay
from repro.index.workloads import sample_keys, wr_workload
from repro.launch.serving import O2ServiceConfig, TuningService


_O2 = O2Config(divergence_threshold=0.05, offline_updates_per_window=2)


def _cfg(**kw) -> LITuneConfig:
    # seq_len=3 < the 4-step windows so replay sampling (and therefore
    # offline fine-tuning) actually runs in these tests
    return LITuneConfig(index_type="alex", episode_len=4, lstm_hidden=16,
                        mlp_hidden=32,
                        ddpg=DDPGConfig(seq_len=3, burn_in=1, batch_size=8),
                        o2=_O2, **kw)


def _windows(n: int, n_keys: int = 512, seed: int = 7):
    """Drifting window stream: the key distribution changes every window,
    so divergence (KS > 0.05) fires from window 1 on."""
    dists = ["uniform", "books", "osm", "fb"]
    wrs = [1.0, 1.0, 3.0, 0.33]
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        data = sample_keys(k, n_keys, dists[i % len(dists)])
        wl, _ = wr_workload(jax.random.fold_in(k, 1), data,
                            wrs[i % len(wrs)], total=n_keys, dist="mix")
        out.append((data, wl, wrs[i % len(wrs)]))
    return out


def _assert_trees_equal(a, b):
    jax.tree.map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


# ------------------------------------------------------------------ parity
def test_service_o2_parity_with_tune_window():
    """The correctness anchor: a single-tenant stream through the service
    with O2 enabled makes the same swap decisions as O2System.tune_window
    on the same windows (each window fits one service tick)."""
    cfg = _cfg()
    budget = 4
    wins = _windows(4)
    wkeys = [jax.random.PRNGKey(50 + i) for i in range(len(wins))]

    serial_tuner = LITune(cfg, seed=0)
    o2sys = O2System(serial_tuner.state, cfg.net_cfg(), cfg.ddpg,
                     cfg.env_cfg(), cfg.et_cfg(), cfg.o2, seed=0)
    serial = [o2sys.tune_window(wkeys[i], d, wl, wr, max_steps=budget)
              for i, (d, wl, wr) in enumerate(wins)]
    assert any(r["divergence"]["diverged"] for r in serial)  # stream drifts

    service = TuningService(LITune(cfg, seed=0), slots=1,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                                               strict_order=True))
    rids = [service.submit(d, wl, wr, budget_steps=budget, key=wkeys[i],
                           noise_scale=0.02)
            for i, (d, wl, wr) in enumerate(wins)]
    results = service.run()
    tenant = service.tenants["alex"]

    for i, rid in enumerate(rids):
        got, want = results[rid], serial[i]
        # same divergence verdicts and same swap decisions, window by window
        assert got["divergence"] == want["divergence"]
        assert got["swapped"] == want["swapped"]
        # and the online episodes themselves stay bitwise identical
        assert got["runtimes"] == want["runtimes"]
        assert got["episode_return"] == want["episode_return"]

    assert tenant.swaps == o2sys.swaps
    assert tenant.monitor.divergences == o2sys.monitor.divergences
    assert tenant.monitor.anchors == o2sys.monitor.anchors

    # the streamed replay is bitwise the serial one
    assert tenant.replay.size == o2sys.replay.size
    n = tenant.replay.size
    for f in ("obs", "action", "reward", "next_obs", "done", "cost",
              "h_a", "c_a", "h_q", "c_q", "step_left"):
        np.testing.assert_array_equal(getattr(tenant.replay, f)[:n],
                                      getattr(o2sys.replay, f)[:n])

    # offline fine-tuning consumed identical batches -> identical params,
    # so online models (after any swaps) agree bitwise too
    _assert_trees_equal(tenant.offline["params"], o2sys.offline["params"])
    _assert_trees_equal(tenant.online["params"], o2sys.online["params"])


def test_stream_via_service_parity_multi_tick_budget():
    """LITune.stream(via_service=True) with a budget that does NOT fit one
    K-ladder tick (5 = K4 + K1 ticks): the offline learner must still run
    exactly one fine-tune round per window — ticks that retire nothing
    skip the learner — so decisions and params match the serial stream."""
    cfg = _cfg()
    wins = _windows(4)
    windows = [(i, d, wl, wr) for i, (d, wl, wr) in enumerate(wins)]

    t_serial = LITune(cfg, seed=0)
    serial = t_serial.stream(iter(windows), max_steps_per_window=5)

    t_serve = LITune(cfg, seed=0)
    served = t_serve.stream(iter(windows), max_steps_per_window=5,
                            via_service=True)

    for got, want in zip(served, serial):
        assert got["window"] == want["window"]
        assert got["divergence"] == want["divergence"]
        assert got["swapped"] == want["swapped"]
        assert got["runtimes"] == want["runtimes"]
    # both tuners keep the same improved model, bitwise
    _assert_trees_equal(t_serve.state["params"], t_serial.state["params"])


def test_stream_via_service_rejects_o2_ablation():
    cfg = _cfg(use_o2=False)
    tuner = LITune(cfg, seed=0)
    windows = [(i, d, wl, wr) for i, (d, wl, wr) in enumerate(_windows(1))]
    with pytest.raises(ValueError, match="use_o2"):
        tuner.stream(iter(windows), via_service=True)


def test_forced_swap_parity_with_tune_window(monkeypatch):
    """Same stream, but assessments always promote the offline model (in
    BOTH paths): swaps and re-anchors line up window by window, and the
    episodes served *after* a hot-swap — from the swapped pool buffers —
    stay bitwise identical to the serial path's post-swap rollouts."""
    import repro.core.o2 as o2mod
    always_win = lambda *a, **k: {"best_runtime_ns": -1.0}  # noqa: E731
    monkeypatch.setattr(o2mod, "assess_offline", always_win)
    # the service's pooled assessments judge through `_pooled_best`
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)

    cfg = _cfg()
    budget = 4
    wins = _windows(4)
    wkeys = [jax.random.PRNGKey(50 + i) for i in range(len(wins))]

    o2sys = O2System(LITune(cfg, seed=0).state, cfg.net_cfg(), cfg.ddpg,
                     cfg.env_cfg(), cfg.et_cfg(), cfg.o2, seed=0)
    serial = [o2sys.tune_window(wkeys[i], d, wl, wr, max_steps=budget)
              for i, (d, wl, wr) in enumerate(wins)]
    assert o2sys.swaps >= 1                      # swaps actually happen

    service = TuningService(LITune(cfg, seed=0), slots=1,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                                               strict_order=True))
    rids = [service.submit(d, wl, wr, budget_steps=budget, key=wkeys[i],
                           noise_scale=0.02)
            for i, (d, wl, wr) in enumerate(wins)]
    results = service.run()
    tenant = service.tenants["alex"]

    for i, rid in enumerate(rids):
        got, want = results[rid], serial[i]
        assert got["divergence"] == want["divergence"]
        assert got["swapped"] == want["swapped"]
        assert got["runtimes"] == want["runtimes"]
    assert tenant.swaps == o2sys.swaps
    assert tenant.monitor.anchors == o2sys.monitor.anchors
    assert tenant.monitor.divergences == o2sys.monitor.divergences
    _assert_trees_equal(tenant.online["params"], o2sys.online["params"])


# ------------------------------------------------------------ swap plumbing
def test_forced_swap_updates_pools_without_retrace(monkeypatch):
    """Offline wins every assessment -> divergence hot-swaps pool params;
    the K-ladder compiled-program cache records zero re-traces across the
    swap (params are program inputs, not closure constants) — and the
    pooled assessments themselves bind zero new step programs."""
    monkeypatch.setattr(o2_runtime, "_pooled_best", lambda *a: -1.0)
    cfg = _cfg(safe_rl=False)   # no early exits: every window is one tick
    service = TuningService(LITune(cfg, seed=0), slots=1,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2))
    wins = _windows(3)
    rids = [service.submit(d, wl, wr, budget_steps=4)
            for d, wl, wr in wins]

    service.step()              # window 0 (reference) completes
    assert rids[0] in service.results
    misses0 = service.program_misses
    resident0 = programs._step_program.cache_info().currsize

    results = service.run()     # windows 1..2 diverge -> forced swaps
    service.flush_o2()          # concurrent mode: verdicts settle here
    tenant = service.tenants["alex"]
    assert results[rids[0]]["swapped"] is False     # reference window
    assert tenant.swaps >= 1
    assert any(results[r]["swapped"] for r in rids[1:])

    # pools now serve the promoted offline model, bitwise
    pool = next(iter(service.pools.values()))
    _assert_trees_equal(jax.device_get(pool.params),
                        jax.device_get(tenant.online["params"]))

    # zero re-traces across the hot-swap: no new program binds, no new
    # compiled executables
    assert service.program_misses == misses0
    assert programs._step_program.cache_info().currsize == resident0
    assert service.stats()["o2"]["alex"]["swaps"] == tenant.swaps


def test_no_swap_when_offline_loses(monkeypatch):
    """Assessments run on diverged windows but the offline model never
    wins: pools keep the original online params and nothing re-anchors."""
    calls = []

    def losing_best(*a):
        calls.append(1)
        return float("inf")

    monkeypatch.setattr(o2_runtime, "_pooled_best", losing_best)
    cfg = _cfg(safe_rl=False)
    tuner = LITune(cfg, seed=0)
    params0 = jax.device_get(tuner.state["params"])
    service = TuningService(tuner, slots=1,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2))
    wins = _windows(3)
    rids = [service.submit(d, wl, wr, budget_steps=4)
            for d, wl, wr in wins]
    results = service.run()
    service.flush_o2()          # concurrent mode: verdicts settle here
    tenant = service.tenants["alex"]

    assert calls                                   # assessments happened
    assert tenant.swaps == 0
    assert all(not results[r]["swapped"] for r in rids)
    assert tenant.monitor.anchors == [0]           # never re-anchored
    pool = next(iter(service.pools.values()))
    _assert_trees_equal(jax.device_get(pool.params), params0)


# ------------------------------------------------- monitor bookkeeping fix
def test_divergence_monitor_bookkeeping():
    m = DivergenceMonitor(_O2)
    k = jax.random.PRNGKey(0)
    d_ref = sample_keys(k, 256, "uniform")
    d_new = sample_keys(jax.random.fold_in(k, 1), 256, "books")

    v1 = m.observe(d_ref, 1.0)
    assert v1 == {"diverged": False, "ks": 0.0, "wr_shift": 0.0}
    # the reference window is recorded, not silently dropped
    assert m.windows_seen == 1
    assert m.divergences == [0.0]
    assert m.anchors == [0]

    v2 = m.observe(d_new, 1.0)
    assert m.divergences == [0.0, v2["ks"]]
    assert v2["ks"] > 0.0 and v2["diverged"]

    # a swap re-anchors the reference and records which window did it
    m.re_anchor(d_new, 1.0)
    assert m.anchors == [0, 1]
    v3 = m.observe(d_new, 1.0)
    assert v3["ks"] == 0.0 and not v3["diverged"]
    # invariant: one divergence entry per window, always
    assert len(m.divergences) == m.windows_seen == 3


def test_o2system_exposes_consistent_monitor_state():
    cfg = _cfg()
    o2 = O2System(LITune(cfg, seed=0).state, cfg.net_cfg(), cfg.ddpg,
                  cfg.env_cfg(), cfg.et_cfg(), cfg.o2, seed=0)
    (d, wl, wr) = _windows(1)[0]
    o2.observe_window(d, wr)
    assert o2.windows_seen == 1
    assert o2.divergences == [0.0]           # first window recorded
    assert o2.ref_quantiles is not None and o2.ref_wr == wr


# ------------------------------------------------------- replay ingestion
def _episode(rng, T, obs_dim=4, act_dim=2, hid=3, done=None):
    if done is None:
        done = np.concatenate([np.zeros(T - 1), [1.0]])
    f32 = lambda *s: rng.standard_normal(s).astype(np.float32)  # noqa: E731
    return dict(
        obs=f32(T, obs_dim), action=f32(T, act_dim), reward=f32(T),
        next_obs=f32(T, obs_dim), done=done.astype(np.float32),
        cost=(rng.random(T) < 0.3).astype(np.float32),
        actor_hidden=(f32(T, hid), f32(T, hid)),
        critic_hidden=(f32(T, hid), f32(T, hid)))


@pytest.mark.parametrize("cap,lens", [
    (512, [10, 3, 7]),                    # two 256-row pages, no wrap
    (32, [5, 7, 9, 6, 8]),                # single page, ring wraps
    (512, [200, 200, 200]),               # page-spanning episodes + wrap
])
def test_device_replay_matches_host_replay(cap, lens):
    """The device-resident packed ring is bitwise the host layout fed the
    same episodes: contents (all ten fields + step_left), ring pointer,
    size, and the sampling RNG draws — including page-boundary writes and
    ring wraparound."""
    from repro.core.replay import DeviceSequenceReplay

    host = SequenceReplay(cap, 4, 2, 3, seq_len=3, seed=0)
    dev = DeviceSequenceReplay(cap, 4, 2, 3, seq_len=3, seed=0)
    rng = np.random.default_rng(1)
    eps = [_episode(rng, T) for T in lens]
    eps.append(_episode(np.random.default_rng(2), 5,
                        done=np.array([0, 1, 0, 0, 1.0])))
    for ep in eps:
        host.add_episode(**ep)
        dev.add_episode(**ep)
    assert (host.ptr, host.size) == (dev.ptr, dev.size)
    for f in ("obs", "action", "reward", "next_obs", "done", "cost",
              "h_a", "c_a", "h_q", "c_q", "step_left"):
        np.testing.assert_array_equal(np.asarray(getattr(dev, f)),
                                      getattr(host, f), err_msg=f)
    b_host = host.sample_sequences(6)
    b_dev = dev.sample_sequences(6)
    for k in b_host:
        np.testing.assert_array_equal(np.asarray(b_dev[k]), b_host[k],
                                      err_msg=k)
    # the stacked multi-batch draw continues the same RNG stream
    s_host = [host.sample_sequences(4) for _ in range(2)]
    s_dev = dev.sample_sequence_batches(2, 4)
    for k in s_host[0]:
        np.testing.assert_array_equal(
            np.asarray(s_dev[k]), np.stack([b[k] for b in s_host]),
            err_msg=k)


def test_batched_assessment_matches_serial_assess_offline():
    """The pooled annex assessment judges each diverged window with
    bitwise the best_runtime_ns `core.o2.assess_offline` reports for the
    same key and params (learner frozen at zero updates so the offline
    params are the deterministic pretrained state)."""
    from repro.core.o2 import assess_offline

    cfg = _cfg(safe_rl=False)
    budget = 4
    wins = _windows(5)
    wkeys = [jax.random.PRNGKey(70 + i) for i in range(len(wins))]

    recorded = []
    real_best = o2_runtime._pooled_best

    def recording_best(r0, runtimes):
        best = real_best(r0, runtimes)
        recorded.append(best)
        return best

    o2_runtime._pooled_best = recording_best
    try:
        service = TuningService(
            LITune(cfg, seed=0), slots=2,
            o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                               offline_updates_per_tick=0))
        for i, (d, wl, wr) in enumerate(wins):
            service.submit(d, wl, wr, budget_steps=budget, key=wkeys[i],
                           noise_scale=0.02)
        results = service.run()
        service.flush_o2()
    finally:
        o2_runtime._pooled_best = real_best

    # serial reference: same PRNG chain (k_off is the second split of the
    # window-key remainder), same pretrained params, same windows
    state0 = LITune(cfg, seed=0).state
    monitor = DivergenceMonitor(cfg.o2)
    want = []
    for i, (d, wl, wr) in enumerate(wins):
        div = monitor.observe(d, wr)
        if div["diverged"]:
            remainder, _ = jax.random.split(wkeys[i])
            k_off = jax.random.split(remainder)[1]
            want.append(assess_offline(
                k_off, state0, cfg.net_cfg(),
                cfg.env_cfg().with_episode_len(budget), cfg.et_cfg(),
                d, wl, wr)["best_runtime_ns"])
    assert want                                   # the stream drifted
    assert len(results) == len(wins)
    assert sorted(recorded) == sorted(want)       # bitwise equality


def test_retired_request_without_admission_verdict_is_skipped():
    """A retired episode whose admission verdict is gone (admitted before
    O2 tracked the tenant, or replayed across a config swap) skips its
    window verdict and is counted, instead of raising mid-tick."""
    cfg = _cfg(safe_rl=False)
    service = TuningService(LITune(cfg, seed=0), slots=1,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2))
    (d, wl, wr) = _windows(1)[0]
    rid = service.submit(d, wl, wr, budget_steps=4)
    service._admit_from_queue()
    service._o2_pending.clear()        # simulate the lost verdict
    results = service.run()
    service.flush_o2()
    assert rid in results
    assert "divergence" not in results[rid]       # verdict skipped...
    assert service.o2_pending_missing == 1        # ...and counted
    assert service.stats()["o2"]["pending_missing"] == 1


def test_concurrent_o2_backpressure_and_flush():
    """Concurrent (non-strict) mode: the learner dispatches with
    backpressure, assessment verdicts settle by flush_o2 at the latest,
    repeated assessments bind no new step programs, and the per-phase
    breakdown is exposed."""
    cfg = _cfg(safe_rl=False)
    service = TuningService(LITune(cfg, seed=0), slots=2,
                            o2=O2ServiceConfig(enabled=True, o2=cfg.o2,
                                               offline_updates_per_tick=2))
    wins = _windows(6)
    rids = [service.submit(d, wl, wr, budget_steps=4)
            for d, wl, wr in wins]
    results = service.run()
    service.flush_o2()
    assert all(r in results for r in rids)
    # every window whose admission verdict existed carries its annotation
    assert all("swapped" in results[r] for r in rids)
    st = service.stats()["o2"]
    t = st["alex"]
    assert t["offline_updates"] + t["finetune_skipped"] > 0
    assert set(st["phase_ms"]) == {"capture", "finetune", "assess"}
    assert st["inflight_assessments"] == 0        # flush settled them

    # a second drifting wave re-uses every resident program: zero new
    # binds, zero new compiled step programs (the no-retrace guarantee
    # covers the assessment path too)
    resident0 = programs._step_program.cache_info().currsize
    misses0 = service.program_misses
    for d, wl, wr in _windows(4, seed=11):
        service.submit(d, wl, wr, budget_steps=4)
    service.run()
    service.flush_o2()
    assert programs._step_program.cache_info().currsize == resident0
    assert service.program_misses == misses0


def test_add_episode_matches_sequential_add():
    """Batched ingestion == T sequential add() calls, bitwise: contents,
    ring pointer, size, step_left back-fill, and subsequent sampling."""
    cases = [
        (1000, [10, 3, 7]),                       # no wraparound
        (32, [5, 7, 9, 6, 8]),                    # ring wraps mid-stream
    ]
    for cap, lens in cases:
        r_seq = SequenceReplay(cap, 4, 2, 3, seq_len=3, seed=0)
        r_bat = SequenceReplay(cap, 4, 2, 3, seq_len=3, seed=0)
        rng = np.random.default_rng(1)
        eps = [_episode(rng, T) for T in lens]
        # one episode with a mid-stream done exercises multi-segment
        # back-fill through the same code path
        eps.append(_episode(np.random.default_rng(2), 5,
                            done=np.array([0, 1, 0, 0, 1.0])))
        for ep in eps:
            for t in range(len(ep["reward"])):
                r_seq.add(ep["obs"][t], ep["action"][t], ep["reward"][t],
                          ep["next_obs"][t], ep["done"][t], ep["cost"][t],
                          (ep["actor_hidden"][0][t],
                           ep["actor_hidden"][1][t]),
                          (ep["critic_hidden"][0][t],
                           ep["critic_hidden"][1][t]))
            r_bat.add_episode(**ep)
        assert (r_seq.ptr, r_seq.size) == (r_bat.ptr, r_bat.size)
        for f in ("obs", "action", "reward", "next_obs", "done", "cost",
                  "h_a", "c_a", "h_q", "c_q", "step_left"):
            np.testing.assert_array_equal(getattr(r_seq, f),
                                          getattr(r_bat, f), err_msg=f)
        b_seq = r_seq.sample_sequences(4)
        b_bat = r_bat.sample_sequences(4)
        for k in b_seq:
            np.testing.assert_array_equal(b_seq[k], b_bat[k])
